"""Barrier-free pipelined execution (incremental exchange manifests).

Covers the PR's invariants end to end:

  * row parity — pipelined mode returns *exactly* the barrier rows for
    every TPC-H query under every shuffle strategy;
  * the partial-manifest protocol (begin / publish / all-submitted gate
    / seal / abort / fresh reset) and its staleness floor;
  * a straggling producer must not gate the consumer's first byte —
    the consumer's sim window opens before the slowest producer ends;
  * result-cache TTL expiry and age/cost-aware capacity eviction;
  * deadline-aware queue ordering (tightest *feasible* deadline first);
  * pilot-scan selectivity probes and EXPLAIN ANALYZE surfacing.
"""

import time

import numpy as np
import pytest

from repro.api import CoordinatorConfig, connect
from repro.core.platform import FaasPlatform, FaultPlan
from repro.core.registry import ResultRegistry, partitions_ready
from repro.data.catalog import Catalog, TableMeta
from repro.service.admission import deadline_order
from repro.sql.physical import PlannerConfig
from repro.sql.queries import QUERIES
from repro.storage import ColumnSpec, ObjectStore, write_pax

PLANNER = PlannerConfig(bytes_per_worker=250_000,
                        broadcast_threshold_bytes=150_000,
                        exchange_partitions=3)

FACT_SCHEMA = [
    ColumnSpec("f_key", "num", "<i8"),
    ColumnSpec("f_grp", "num", "<i8"),
    ColumnSpec("f_val", "num", "<f8"),
]


def _run(store, catalog, sql, *, pipelined, planner=PLANNER,
         platform=None, adaptive=False):
    cfg = CoordinatorConfig(planner=planner, use_result_cache=False,
                            adaptive=adaptive, pipelined=pipelined)
    kwargs = {"platform": platform} if platform is not None \
        else {"quota": 1000}
    with connect(store, catalog, config=cfg, **kwargs) as session:
        res = session.submit(sql).result(timeout=300)
        cols = res.fetch(store)
    return cols, res.stats


def _sorted_rows(cols):
    keys = sorted(cols)
    arrs = [np.asarray(cols[k], np.float64) for k in keys]
    order = np.lexsort(arrs)
    return {k: a[order] for k, a in zip(keys, arrs)}


def _assert_same_rows(a, b, ctx=""):
    sa, sb = _sorted_rows(a), _sorted_rows(b)
    assert sorted(sa) == sorted(sb), ctx
    for k in sa:
        np.testing.assert_allclose(sa[k], sb[k], rtol=1e-9, atol=1e-9,
                                   err_msg=f"{ctx} :: {k}")


DIM_SCHEMA = [
    ColumnSpec("d_key", "num", "<i8"),
    ColumnSpec("d_x", "num", "<i8"),
]
# the binder requires FK→PK joins; register the dim PK
import repro.sql.logical as _logical  # noqa: E402
_logical.PRIMARY_KEYS.setdefault("adim", "d_key")


def _make_fact(rows=4000, n_parts=4, groups=6, dim_rows=50, seed=0):
    rng = np.random.default_rng(seed)
    fact = {
        "f_key": rng.integers(0, dim_rows, rows).astype(np.int64),
        "f_grp": rng.integers(0, groups, rows).astype(np.int64),
        "f_val": np.round(rng.normal(0, 10, rows), 3),
    }
    dim = {
        "d_key": np.arange(dim_rows, dtype=np.int64),
        "d_x": rng.integers(0, 5, dim_rows).astype(np.int64),
    }
    store = ObjectStore(tier="local", seed=seed)
    catalog = Catalog()
    files = []
    for p in range(n_parts):
        sel = slice(p * rows // n_parts, (p + 1) * rows // n_parts)
        key = f"db/afact/part-{p:05d}.spax"
        store.put(key, write_pax({k: v[sel] for k, v in fact.items()},
                                 FACT_SCHEMA))
        files.append(key)
    catalog.add(TableMeta("afact", FACT_SCHEMA, files, rows, 400_000))
    store.put("db/adim/part-00000.spax", write_pax(dim, DIM_SCHEMA))
    catalog.add(TableMeta("adim", DIM_SCHEMA,
                          ["db/adim/part-00000.spax"], dim_rows, 300_000))
    return store, catalog


# -- tentpole: pipelined ≡ barrier on TPC-H × every shuffle strategy ----------

@pytest.mark.parametrize("strategy", ["direct", "combining",
                                      "multilevel"])
@pytest.mark.parametrize("qname", sorted(QUERIES))
def test_pipelined_matches_barrier_rows_tpch(tpch_store, qname,
                                             strategy):
    store, catalog = tpch_store
    planner = PlannerConfig(bytes_per_worker=250_000,
                            broadcast_threshold_bytes=150_000,
                            exchange_partitions=3,
                            exchange_strategy=strategy)
    barrier_cols, _ = _run(store, catalog, QUERIES[qname],
                           pipelined=False, planner=planner)
    piped_cols, piped_stats = _run(store, catalog, QUERIES[qname],
                                   pipelined=True, planner=planner)
    _assert_same_rows(barrier_cols, piped_cols, f"{qname}/{strategy}")
    # multi-pipeline plans must actually have exercised partial-input
    # admission, not silently fallen back to barrier resolution
    if len(piped_stats.pipelines) > 1:
        assert any(r.pipelined for r in piped_stats.pipelines), qname


# -- partial-manifest protocol ------------------------------------------------

def test_partial_manifest_protocol():
    store = ObjectStore(tier="local", seed=0)
    reg = ResultRegistry(store)
    key = reg.begin_partial("s1", n_producers=4, prefix="results/s1")
    assert key == reg.partial_key("s1")
    man = reg.partial_manifest("s1")
    assert man["n_producers"] == 4 and man["done"] == {}

    # half the fleet lands: the 0.5 admission gate only opens once the
    # whole fleet is *submitted* (deadlock-freedom), then stays open
    reg.publish_partial("s1", 0, {"rows": 10})
    reg.publish_partial("s1", 1, {"rows": 12})
    assert not partitions_ready(reg.partial_manifest("s1"), 0.5)
    reg.mark_all_submitted("s1", 4)
    assert partitions_ready(reg.partial_manifest("s1"), 0.5)
    assert not partitions_ready(reg.partial_manifest("s1"), 0.9)

    # a reassignment split grows the fleet past the plan
    reg.publish_partial("s1", 2, {"rows": 9})
    reg.publish_partial("s1", 3, {"rows": 9})
    reg.publish_partial("s1", 4, {"rows": 1}, n_producers=5)
    reg.finish_partial("s1", n_producers=5)
    man = reg.partial_manifest("s1")
    assert man["complete"] and man["n_producers"] == 5
    assert partitions_ready(man, 1.0)


def test_admission_fraction_cost_model():
    from repro.core.cost import CostModel
    # no observations → the seed's 0.5 constant
    assert CostModel.pipeline_admission_fraction([]) == 0.5
    # uniform fleet: the k-statistic is the same instant for every k,
    # so late admission avoids pure top-up overhead
    assert CostModel.pipeline_admission_fraction([1.0, 1.0, 1.0, 1.0]) == 1.0
    # one straggler: admit at 3/4 and overlap its tail
    assert CostModel.pipeline_admission_fraction([1.0, 1.0, 1.0, 5.0]) == 0.75


def test_partitions_ready_auto_fraction_from_wall_s():
    """fraction=None derives the gate from landed producer wall clocks;
    without wall_s observations it falls back to the 0.5 constant."""
    from repro.core.cost import CostModel
    store = ObjectStore(tier="local", seed=0)
    reg = ResultRegistry(store)
    reg.begin_partial("s2", n_producers=4, prefix="results/s2")
    reg.mark_all_submitted("s2", 4)
    reg.publish_partial("s2", 0, {"rows": 1, "wall_s": 1.0})
    reg.publish_partial("s2", 1, {"rows": 1, "wall_s": 1.0})
    # uniform walls so far → model wants the full fleet
    assert not partitions_ready(reg.partial_manifest("s2"), None,
                                cost_model=CostModel)
    reg.publish_partial("s2", 2, {"rows": 1, "wall_s": 1.0})
    reg.publish_partial("s2", 3, {"rows": 1, "wall_s": 1.0})
    assert partitions_ready(reg.partial_manifest("s2"), None,
                            cost_model=CostModel)

    reg.begin_partial("s3", n_producers=4, prefix="results/s3")
    reg.mark_all_submitted("s3", 4)
    reg.publish_partial("s3", 0, {"rows": 1})
    reg.publish_partial("s3", 1, {"rows": 1})
    assert partitions_ready(reg.partial_manifest("s3"), None,
                            cost_model=CostModel)


def test_topup_read_cost_from_manifest_info():
    """Top-up ordering reads per-partition byte costs off the partial
    manifest; absent or malformed info prices as zero (read last)."""
    from repro.exec.fragment import _read_cost
    assert _read_cost({"bytes": 512}) == 512
    assert _read_cost({"rows": 9}) == 0
    assert _read_cost(None) == 0
    assert _read_cost("junk") == 0


def test_begin_partial_resets_aborted_stream():
    """A re-claimant of a failed execution must not inherit the dead
    owner's poison flag — begin_partial writes the stream fresh, only
    the version survives."""
    store = ObjectStore(tier="local", seed=0)
    reg = ResultRegistry(store)
    reg.begin_partial("s2", n_producers=3, prefix="results/s2")
    reg.publish_partial("s2", 0, {"rows": 5})
    reg.abort_partial("s2")
    assert reg.partial_manifest("s2")["aborted"]
    v = reg.partial_manifest("s2")["version"]

    reg.begin_partial("s2", n_producers=2, prefix="results/s2")
    man = reg.partial_manifest("s2")
    assert not man["aborted"] and man["done"] == {}
    assert man["n_producers"] == 2 and man["version"] == v + 1


def test_await_source_ready_rejects_stale_complete_entry():
    """The freshness floor: a complete entry published by an *earlier*
    query (possibly under a different fleet layout) is ignored when the
    producer is re-executing — the live partial stream decides."""
    store = ObjectStore(tier="local", seed=0)
    reg = ResultRegistry(store)
    reg.register("s3", prefix="results/s3", n_fragments=8,
                 partitioning={"kind": "single"}, schema=[])
    floor = time.time()

    # without a floor the stale entry is returned immediately
    assert reg.await_source_ready(
        "s3", fraction=0.5, timeout_s=0.2)["n_fragments"] == 8

    # with the floor it is not: the fresh partial stream gates instead
    reg.begin_partial("s3", n_producers=2, prefix="results/s3")
    with pytest.raises(TimeoutError):
        reg.await_source_ready("s3", fraction=0.5, timeout_s=0.2,
                               min_published_at=floor)
    reg.publish_partial("s3", 0, {"rows": 3})
    reg.mark_all_submitted("s3", 2)
    assert reg.await_source_ready("s3", fraction=0.5, timeout_s=0.2,
                                  min_published_at=floor) is None

    # re-publish (the re-execution's barrier entry) passes the floor
    reg.register("s3", prefix="results/s3", n_fragments=2,
                 partitioning={"kind": "single"}, schema=[])
    entry = reg.await_source_ready("s3", fraction=0.5, timeout_s=0.2,
                                   min_published_at=floor)
    assert entry["n_fragments"] == 2


def test_aborted_stream_raises_for_waiters():
    store = ObjectStore(tier="local", seed=0)
    reg = ResultRegistry(store)
    reg.begin_partial("s4", n_producers=2, prefix="results/s4")
    reg.abort_partial("s4")
    with pytest.raises(RuntimeError):
        reg.await_source_ready("s4", fraction=0.5, timeout_s=0.2)


# -- straggler: slowest producer must not gate consumer first byte ------------

def test_straggler_does_not_gate_consumer_start():
    store, catalog = _make_fact()
    sql = ("select f_grp, sum(f_val) as s from afact "
           "group by f_grp order by f_grp")
    planner = PlannerConfig(bytes_per_worker=80_000,
                            broadcast_threshold_bytes=150_000,
                            exchange_partitions=3)
    # fragment 0 of the scan fleet straggles ×50 in sim time; straggler
    # re-triggering is defeated by straggling every attempt of it
    faults = FaultPlan(straggle_fragments=tuple(
        (0, 0, a) for a in range(0, 300)), straggler_factor=50.0)

    b_cols, b_stats = _run(store, catalog, sql, pipelined=False,
                           planner=planner,
                           platform=FaasPlatform(seed=0, faults=faults))
    p_cols, p_stats = _run(store, catalog, sql, pipelined=True,
                           planner=planner,
                           platform=FaasPlatform(seed=0, faults=faults))
    _assert_same_rows(b_cols, p_cols, "straggler")

    producers = {r.pid: r for r in p_stats.pipelines}
    consumers = [r for r in p_stats.pipelines if r.pipelined]
    assert consumers, "no pipeline consumed partial input"
    scan = producers[0]
    for c in consumers:
        # first byte strictly before the straggler-dominated finish
        assert c.sim_start_s < scan.sim_end_s, (c.pid, c.sim_start_s,
                                                scan.sim_end_s)
    # overlapping the straggler tail beats the barrier (stage-serial)
    # schedule of the *same* observed runtimes — cross-run latencies
    # are not comparable (each platform draws its own start jitter)
    serial = sum(r.sim_s for r in p_stats.pipelines if not r.cache_hit)
    assert p_stats.sim_latency_s < serial


# -- result cache: TTL + age/cost-aware eviction ------------------------------

def _entry(reg, sem, cents):
    reg.register(sem, prefix=f"results/{sem}", n_fragments=1,
                 partitioning={"kind": "single"}, schema=[],
                 cost_cents=cents)


def test_result_cache_ttl_expiry():
    store = ObjectStore(tier="local", seed=0)
    reg = ResultRegistry(store, result_ttl_s=0.05)
    _entry(reg, "t1", 1.0)
    assert reg.lookup("t1") is not None
    time.sleep(0.08)
    assert reg.lookup("t1") is None          # lazily expired
    assert reg.evictions == 1


def test_result_cache_capacity_eviction_prefers_cheap_old():
    store = ObjectStore(tier="local", seed=0)
    reg = ResultRegistry(store, max_entries=2)
    _entry(reg, "old-cheap", 0.001)
    time.sleep(0.02)
    _entry(reg, "old-costly", 100.0)
    time.sleep(0.02)
    _entry(reg, "new", 0.001)                # capacity hit: one evicted
    assert reg.lookup("old-cheap") is None   # lowest cost/age score
    assert reg.lookup("old-costly") is not None
    assert reg.lookup("new") is not None
    assert reg.evictions == 1


# -- deadline-aware queue ordering --------------------------------------------

class _Q:
    def __init__(self, rid, tenant, deadline_s, submitted_at):
        self.request_id = rid
        self.tenant = tenant
        self.deadline_s = deadline_s
        self.submitted_at = submitted_at


def test_deadline_order_feasible_first():
    est = {"fast": 1.0, "slow": 50.0}.get
    qs = [
        _Q("a", "fast", None, 0.0),      # FIFO band
        _Q("b", "fast", 10.0, 1.0),      # feasible, loose deadline
        _Q("c", "slow", 5.0, 2.0),       # infeasible: est 50 > 5
        _Q("d", "fast", 2.0, 3.0),       # feasible, tightest deadline
        _Q("e", "new", 4.0, 4.0),        # no estimate → optimistic
        _Q("f", None, None, 0.5),        # FIFO band, older than nothing
    ]
    got = [q.request_id for q in deadline_order(qs, est)]
    # tightest feasible deadlines, then FIFO no-deadline, then infeasible
    assert got == ["d", "e", "b", "a", "f", "c"]


def test_deadline_order_infeasible_never_displaces():
    est = lambda t: 100.0   # noqa: E731 - everything infeasible
    qs = [_Q("x", "t", 1.0, 0.0), _Q("y", "t", None, 1.0)]
    got = [q.request_id for q in deadline_order(qs, est)]
    assert got == ["y", "x"]    # the lost SLO yields to the FIFO band


# -- pilot scan + EXPLAIN ANALYZE ---------------------------------------------

def test_pilot_scan_calibrates_selectivity():
    """An *uncalibrated* filter→scan fleet is preceded by a one-unit
    probe whose observed selectivity corrects the stage's row estimate
    and lands in the calibration store — so the second run of the same
    filter signature probes nothing."""
    store, catalog = _make_fact(rows=8000, n_parts=8)
    # join probe side: a pure filter→scan pipeline (a grouped-agg scan
    # pipeline measures post-aggregation rows, so it is never probed)
    sql = ("select d_x, count(*) as n from afact, adim "
           "where f_key = d_key and f_val > 25 group by d_x order by d_x")
    cfg = CoordinatorConfig(planner=PlannerConfig(
        bytes_per_worker=40_000, broadcast_threshold_bytes=1,
        exchange_partitions=3), use_result_cache=False, adaptive=True,
        pipelined=True)
    with connect(store, catalog, config=cfg, quota=1000) as session:
        res = session.submit(sql).result(timeout=300)
        pilots = [a for p in res.stats.pipelines for a in p.adaptations
                  if a["kind"] == "pilot_scan"]
        assert pilots and 0.0 <= pilots[0]["selectivity"] <= 1.0
        assert pilots[0]["unit_rows"] > 0
        # calibrated now: the repeat run must skip the probe
        res2 = session.submit(sql).result(timeout=300)
        again = [a for p in res2.stats.pipelines for a in p.adaptations
                 if a["kind"] == "pilot_scan"]
        assert not again


def test_explain_analyze_shows_pipelined_window(tpch_store):
    store, catalog = tpch_store
    cfg = CoordinatorConfig(planner=PLANNER, use_result_cache=False,
                            pipelined=True)
    with connect(store, catalog, config=cfg, quota=1000) as session:
        text = session.submit(QUERIES["q3"]).explain_analyze(timeout=300)
    assert "pipelined: window" in text
    assert "pilot-K" in text
