"""Property-based tests (hypothesis): the distributed serverless engine
must agree with the numpy oracle on randomly generated queries over
randomly generated tables — the system invariant behind the paper's
idempotent re-execution guarantees."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import CoordinatorConfig, FaasPlatform, QueryCoordinator
from repro.data.catalog import Catalog, TableMeta
from repro.sql import oracle
from repro.sql.logical import Binder
from repro.sql.parser import parse
from repro.sql.physical import PlannerConfig
from repro.sql.rules import optimize
from repro.storage import ColumnSpec, ObjectStore, write_pax

SCHEMA = [
    ColumnSpec("f_key", "num", "<i8"),
    ColumnSpec("f_a", "num", "<i8"),
    ColumnSpec("f_b", "num", "<f8"),
    ColumnSpec("f_c", "dict", "<i4", ("P", "Q", "R")),
]
DIM_SCHEMA = [
    ColumnSpec("d_key", "num", "<i8"),
    ColumnSpec("d_x", "num", "<i8"),
]
# the binder requires FK→PK joins; register the dim PK
import repro.sql.logical as _logical
_logical.PRIMARY_KEYS.setdefault("dim", "d_key")


def _make_db(rows, dim_rows, seed):
    rng = np.random.default_rng(seed)
    fact = {
        "f_key": rng.integers(0, max(dim_rows * 2, 1), rows
                              ).astype(np.int64),
        "f_a": rng.integers(-50, 50, rows).astype(np.int64),
        "f_b": np.round(rng.normal(0, 10, rows), 3),
        "f_c": rng.integers(0, 3, rows).astype(np.int32),
    }
    dim = {
        "d_key": np.arange(dim_rows, dtype=np.int64),
        "d_x": rng.integers(0, 7, dim_rows).astype(np.int64),
    }
    store = ObjectStore(tier="local", seed=seed)
    catalog = Catalog()
    files = []
    n_parts = 3
    for p in range(n_parts):
        sel = slice(p * rows // n_parts, (p + 1) * rows // n_parts)
        key = f"db/fact/part-{p:05d}.spax"
        store.put(key, write_pax({k: v[sel] for k, v in fact.items()},
                                 SCHEMA))
        files.append(key)
    catalog.add(TableMeta("fact", SCHEMA, files, rows, 10_000))
    store.put("db/dim/part-00000.spax", write_pax(dim, DIM_SCHEMA))
    catalog.add(TableMeta("dim", DIM_SCHEMA,
                          ["db/dim/part-00000.spax"], dim_rows, 1_000))
    return store, catalog, {"fact": fact, "dim": dim}


cmp_ops = st.sampled_from(["<", "<=", ">", ">=", "=", "<>"])
agg_fns = st.sampled_from(["sum", "min", "max", "count"])


@st.composite
def queries(draw):
    conj = []
    for _ in range(draw(st.integers(0, 2))):
        col = draw(st.sampled_from(["f_a", "f_b"]))
        op = draw(cmp_ops)
        lit = draw(st.integers(-40, 40))
        conj.append(f"{col} {op} {lit}")
    if draw(st.booleans()):
        vals = draw(st.lists(st.sampled_from(["P", "Q", "R"]),
                             min_size=1, max_size=2, unique=True))
        conj.append("f_c in (" + ", ".join(f"'{v}'" for v in vals) + ")")
    join = draw(st.booleans())
    # f_c → dict-coded (one-hot / segmented min-max arms), f_key → many
    # non-dict groups (sort-strategy arm), d_x → grouped join probe
    group = draw(st.sampled_from([None, "f_c", "f_a", "f_key",
                                  "d_x" if join else "f_c"]))
    fn = draw(agg_fns)
    agg = "count(*)" if fn == "count" else f"{fn}(f_b + 0.5 * f_a)"
    if group:
        select = f"{group}, {agg} as r"
        tail = f" group by {group} order by {group}"
        limit = draw(st.sampled_from([None, 3]))
        if limit:                    # final ORDER BY … LIMIT → top-k arm
            tail += f" limit {limit}"
    else:
        select = f"{agg} as r"
        tail = ""
    frm = "fact, dim" if join else "fact"
    where = list(conj)
    if join:
        where.append("f_key = d_key")
    wsql = (" where " + " and ".join(where)) if where else ""
    return f"select {select} from {frm}{wsql}{tail}"


@settings(max_examples=25, deadline=None)
@given(sql=queries(), seed=st.integers(0, 3),
       pipelined=st.booleans(), fused=st.booleans(),
       semijoin=st.sampled_from(["off", "auto", "forced"]),
       strategy=st.sampled_from(["direct", "combining", "multilevel"]))
def test_engine_matches_oracle(sql, seed, pipelined, fused, semijoin,
                               strategy):
    """Random queries × {barrier, pipelined} × every shuffle strategy ×
    {fused kernels, generic jnp} × {no filters, cost-gated filters,
    force-pushed filters} must all agree with the numpy oracle —
    barrier-free admission, incremental top-up reads, the kernel
    dispatch layer, and semi-join filter pushdown are invisible to
    query results."""
    from repro.exec import lower
    store, catalog, tables = _make_db(900, 40, seed)
    plan, _ = Binder(catalog).bind(parse(sql))
    want = oracle.run(optimize(plan), tables)
    coord = QueryCoordinator(
        store, catalog, platform=FaasPlatform(seed=seed),
        config=CoordinatorConfig(
            pipelined=pipelined,
            # "forced" overrides the cost gate, which would otherwise
            # always decline at this scale; adaptive off so the pilot-K
            # re-gate cannot un-force it before the probe launches
            adaptive=semijoin != "forced",
            planner=PlannerConfig(
                semijoin=semijoin != "off",
                bytes_per_worker=3_000,
                # a broadcast join has no probe exchange to filter —
                # forced mode drives the dim through a repartition join
                broadcast_threshold_bytes=1 if semijoin == "forced"
                else 2_000,
                exchange_partitions=2, exchange_strategy=strategy)))
    pplan = coord.plan_sql(sql)
    if semijoin == "forced":
        for p in pplan.pipelines.values():
            if p.params.semijoin:
                p.params.semijoin["enabled"] = True
    if fused:
        got = coord.execute_plan(pplan).fetch(store)
    else:
        with lower.disabled():
            got = coord.execute_plan(pplan).fetch(store)
    n_want = len(next(iter(want.values()))) if want else 0
    n_got = len(next(iter(got.values()))) if got else 0
    # empty aggregates: a scalar agg over zero rows yields one masked row
    # upstream; oracle yields identity — compare only non-empty results
    if n_want == 0 or n_got == 0:
        assert n_want == n_got or "group by" not in sql
        return
    order = np.lexsort([want[k] for k in sorted(want)])
    order_g = np.lexsort([got[k] for k in sorted(want)])
    for k in want:
        np.testing.assert_allclose(
            np.asarray(got[k], np.float64)[order_g],
            np.asarray(want[k], np.float64)[order],
            rtol=1e-9, atol=1e-9, err_msg=f"{sql} :: {k}")
