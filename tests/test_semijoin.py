"""Semi-join filter pushdown: Bloom filter kernels, the cost gate, the
pilot-K adopt/revoke loop, and end-to-end filtered-vs-unfiltered parity.

The correctness invariant under test everywhere: a Bloom filter has no
false negatives, so a filtered probe produces exactly the rows an
unfiltered probe produces — filters only change *where* rows die (on the
scanning worker instead of after the shuffle), never *which* rows
survive the join.
"""

import numpy as np
import pytest

from repro.api import ChaosConfig, ChaosEngine, connect
from repro.core import CoordinatorConfig, FaasPlatform, QueryCoordinator
from repro.core.cost import CostModel
from repro.core.engine import explain_analyze
from repro.core.registry import ResultRegistry
from repro.data import generate_tpch
from repro.kernels import bloom
from repro.sql.physical import PlannerConfig
from repro.storage import ObjectStore

# a selective build side: few orders pass the price predicate, so most
# lineitem probe rows have no join partner and die at the filter
SELECTIVE_JOIN = (
    "select l_orderkey, sum(l_extendedprice) as rev "
    "from lineitem, orders "
    "where l_orderkey = o_orderkey and o_totalprice > 500000 "
    "group by l_orderkey")

PLANNER = dict(bytes_per_worker=250_000, broadcast_threshold_bytes=1,
               exchange_partitions=3)


def _coordinator(store, catalog, *, semijoin=True, pipelined=False,
                 adaptive=False, seed=1):
    cfg = CoordinatorConfig(
        planner=PlannerConfig(semijoin=semijoin, **PLANNER),
        use_result_cache=False, calibrate_selectivity=False,
        pipelined=pipelined, adaptive=adaptive)
    return QueryCoordinator(store, catalog,
                            platform=FaasPlatform(seed=seed), config=cfg)


def _force_enable(plan, flag=True):
    """Override the plan-time cost verdict (sf=0.01 is far below the
    gate's break-even scale; the plumbing is the system under test)."""
    for p in plan.pipelines.values():
        if p.params.semijoin:
            p.params.semijoin["enabled"] = flag


def _sorted_rows(cols):
    keys = sorted(cols)
    arrs = [np.asarray(cols[k], np.float64) for k in keys]
    order = np.lexsort(arrs)
    return {k: a[order] for k, a in zip(keys, arrs)}


def _assert_same_rows(a, b, ctx=""):
    sa, sb = _sorted_rows(a), _sorted_rows(b)
    assert sorted(sa) == sorted(sb), ctx
    for k in sa:
        np.testing.assert_allclose(sa[k], sb[k], rtol=1e-9, atol=1e-9,
                                   err_msg=f"{ctx} :: {k}")


# -- filter primitives ---------------------------------------------------------

@pytest.mark.parametrize("n_keys", [100, 5_000, 200_000])
def test_bloom_no_false_negatives_and_fpr_bound(n_keys):
    rng = np.random.default_rng(n_keys)
    keys = rng.choice(np.arange(4 * n_keys, dtype=np.uint32),
                      size=n_keys, replace=False)
    bits = bloom.bloom_bits_for(n_keys)
    words = bloom.bloom_build(keys, bits)
    # every inserted key hits — the no-false-negative guarantee
    assert bloom.bloom_probe_np(keys, words, bits).all()
    # non-members pass at roughly the theoretical rate
    others = np.setdiff1d(
        rng.integers(4 * n_keys, 2**31, 4 * n_keys).astype(np.uint32),
        keys)
    fpr = bloom.bloom_probe_np(others, words, bits).mean()
    want = bloom.bloom_fpr(n_keys, bits)
    assert fpr <= max(3.0 * want, 0.01), (fpr, want)


def test_bloom_bits_pow2_and_clamped():
    for n in (0, 1, 7, 1000, 10**9):
        bits = bloom.bloom_bits_for(n)
        assert bits & (bits - 1) == 0
        assert bloom.BLOOM_MIN_BITS <= bits <= bloom.BLOOM_MAX_BITS
    assert bloom.bloom_bits_for(10**9) == bloom.BLOOM_MAX_BITS


def test_bloom_merge_equals_single_build():
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 2**31, 9_000).astype(np.uint32)
    bits = bloom.bloom_bits_for(keys.size)
    merged = bloom.bloom_merge(
        [bloom.bloom_build(part, bits)
         for part in np.array_split(keys, 7)])
    np.testing.assert_array_equal(merged, bloom.bloom_build(keys, bits))


def test_bloom_wire_roundtrip():
    words = bloom.bloom_build(np.arange(500, dtype=np.uint32),
                              bloom.bloom_bits_for(500))
    wire = bloom.bloom_to_wire(words, mode="hash64")
    assert isinstance(wire["words"], bytes)      # msgpack-safe
    back = bloom.bloom_from_wire(wire)
    assert back["bits"] == words.size * 32
    assert back["mode"] == "hash64"
    np.testing.assert_array_equal(back["words"], words)


@pytest.mark.parametrize("n_rows", [900, 3000, 12000])
def test_probe_np_jnp_pallas_bit_parity(n_rows):
    """All three probe paths share one hash family — the masks must be
    bit-identical, not just statistically alike."""
    import jax.numpy as jnp
    rng = np.random.default_rng(n_rows)
    members = rng.integers(0, 50_000, 2_000).astype(np.uint32)
    bits = bloom.bloom_bits_for(members.size)
    words = bloom.bloom_build(members, bits)
    probe = rng.integers(0, 100_000, n_rows).astype(np.int64)

    m_np = bloom.bloom_probe_np(probe.astype(np.uint32), words, bits)
    m_jnp = np.asarray(bloom.bloom_probe_jnp(
        jnp.asarray(probe), jnp.asarray(words), bits=bits))
    m_pls = np.asarray(bloom.fused_bloom_filter(
        {"key": jnp.asarray(probe)}, jnp.ones(n_rows, dtype=bool),
        pred=None, key="key", words=words, bits=bits, interpret=True))
    np.testing.assert_array_equal(m_np, m_jnp)
    np.testing.assert_array_equal(m_np, m_pls)


# -- the cost gate -------------------------------------------------------------

def test_semijoin_benefit_monotone_in_match_fraction():
    cm = CostModel()
    args = dict(producers=64, n_dest=32, probe_bytes=2e9,
                build_distinct=50_000)
    benefits = [cm.semijoin_benefit(match_fraction=m, **args)
                ["benefit_cents"] for m in (0.01, 0.1, 0.5, 0.9, 1.0)]
    assert all(a >= b for a, b in zip(benefits, benefits[1:]))
    # a selective filter over a big probe pays for itself…
    assert benefits[0] > 0
    # …a PK-FK join (every probe row matches) never does
    assert benefits[-1] < 0


def test_l0_tier_choice_prefers_express_for_small_hot_intermediates():
    cm = CostModel()
    assert cm.l0_tier_choice(16, 1_000_000) == "s3-express"
    # large long-lived spill: express storage premium dominates
    assert cm.l0_tier_choice(4, 50e9, ttl_s=3600.0) == "s3-standard"


# -- pilot-K adopt / revoke ----------------------------------------------------

def test_reoptimizer_adopts_and_revokes_from_observed_build(tpch_store):
    from repro.core.adaptive import Reoptimizer
    store, catalog = tpch_store
    coord = _coordinator(store, catalog)
    plan = coord.plan_sql(SELECTIVE_JOIN)
    probe = next(p for p in plan.pipelines.values() if p.params.semijoin)
    sj = probe.params.semijoin
    # scale the probe to where the gate's economics actually bite
    probe.params.est_out_bytes = int(2e9)
    reopt = Reoptimizer(CostModel())

    sj["enabled"] = False
    a = reopt.semijoin_decision(probe, build_rows=0.01 * sj["base_rows"])
    assert a is not None and a["kind"] == "semijoin_adopt"
    assert sj["enabled"] and a["match_fraction"] <= 0.02

    a = reopt.semijoin_decision(probe, build_rows=float(sj["base_rows"]))
    assert a is not None and a["kind"] == "semijoin_revoke"
    assert not sj["enabled"] and a["match_fraction"] == 1.0

    # verdict unchanged → no adaptation record (hysteresis, no churn)
    assert reopt.semijoin_decision(
        probe, build_rows=float(sj["base_rows"])) is None


# -- end-to-end ----------------------------------------------------------------

def _run_plan(coord, plan):
    res = coord.execute_plan(plan)
    return res, res.fetch(coord.store)


def test_filtered_probe_matches_unfiltered_and_shrinks_shuffle(tpch_store):
    store, catalog = tpch_store
    coord = _coordinator(store, catalog)
    plan = coord.plan_sql(SELECTIVE_JOIN)
    _force_enable(plan)
    probe_pid = next(pid for pid, p in plan.pipelines.items()
                     if p.params.semijoin)
    filt, got = _run_plan(coord, plan)

    off = _coordinator(store, catalog, semijoin=False, seed=2)
    unf, want = _run_plan(off, off.plan_sql(SELECTIVE_JOIN))

    _assert_same_rows(got, want, "filtered vs unfiltered")

    pf = next(r for r in filt.stats.pipelines if r.pid == probe_pid)
    pu = next(r for r in unf.stats.pipelines if r.pid == probe_pid)
    assert pf.semijoin and pf.semijoin["applied"]
    assert pf.semijoin_killed > 0
    # the acceptance bar: ≥3× fewer probe-side shuffled bytes and
    # strictly fewer storage requests at identical result rows
    assert pu.bytes_written >= 3 * pf.bytes_written, \
        (pu.bytes_written, pf.bytes_written)
    assert sum(r.requests for r in filt.stats.pipelines) < \
        sum(r.requests for r in unf.stats.pipelines)

    text = explain_analyze(plan, filt.stats)
    assert "semijoin: pushed" in text
    assert f"actual={pf.semijoin_killed}" in text


def test_sem_hash_unchanged_by_filter_toggle(tpch_store):
    """Gate-on and gate-off runs must share one result-cache entry: the
    sem hash folds the *build side*, not the verdict."""
    store, catalog = tpch_store
    coord = _coordinator(store, catalog)
    p1 = coord.plan_sql(SELECTIVE_JOIN)
    p2 = coord.plan_sql(SELECTIVE_JOIN)
    _force_enable(p2)
    assert {p.sem_hash for p in p1.pipelines.values()} == \
        {p.sem_hash for p in p2.pipelines.values()}
    # but a semijoin-off *plan* must not collide with the annotated one
    off = _coordinator(store, catalog, semijoin=False)
    p3 = off.plan_sql(SELECTIVE_JOIN)
    probe = next(p for p in p1.pipelines.values() if p.params.semijoin)
    assert probe.sem_hash not in {p.sem_hash
                                  for p in p3.pipelines.values()}


def test_pipelined_pilot_revokes_uneconomic_filter():
    """At sf=0.01 the true benefit is negative: the pilot-K peek at the
    build's partial manifest must revoke a (forced) filter before the
    probe pays the sealed-filter wait — and parity must hold.

    Fresh store: an earlier unfiltered run of the same build pipeline
    would leave a complete bloomless registry entry for the build's sem
    hash, short-circuiting the probe to the (also correct, but
    different) "filter unavailable" fallback."""
    store = ObjectStore(tier="local", seed=0)
    catalog = generate_tpch(store, sf=0.01, n_parts=4, seed=0)
    coord = _coordinator(store, catalog, pipelined=True, adaptive=True,
                         seed=3)
    plan = coord.plan_sql(SELECTIVE_JOIN)
    _force_enable(plan)
    res, got = _run_plan(coord, plan)
    pr = next(r for r in res.stats.pipelines if r.semijoin is not None)
    assert not pr.semijoin["applied"]
    assert any(a.get("kind") == "semijoin_revoke" for a in pr.adaptations)

    off = _coordinator(store, catalog, semijoin=False, seed=4)
    _, want = _run_plan(off, off.plan_sql(SELECTIVE_JOIN))
    _assert_same_rows(got, want, "pilot-revoked vs unfiltered")


def test_bloomless_cached_build_falls_back_unfiltered():
    """A build exchange first materialized by an unfiltered query leaves
    a complete registry entry with no published filter. A later probe
    that wants the filter must not wait for one that will never arrive —
    it launches unfiltered against the shared build output."""
    store = ObjectStore(tier="local", seed=0)
    catalog = generate_tpch(store, sf=0.01, n_parts=4, seed=0)
    off = _coordinator(store, catalog, semijoin=False, seed=6)
    _, want = _run_plan(off, off.plan_sql(SELECTIVE_JOIN))

    # result cache ON: the build pipeline is adopted from the registry
    # (bloomless) instead of re-executing and re-publishing its filter
    cfg = CoordinatorConfig(
        planner=PlannerConfig(semijoin=True, **PLANNER),
        use_result_cache=True, calibrate_selectivity=False,
        pipelined=False, adaptive=False, semijoin_wait_timeout_s=2.0)
    coord = QueryCoordinator(store, catalog,
                             platform=FaasPlatform(seed=7), config=cfg)
    plan = coord.plan_sql(SELECTIVE_JOIN)
    _force_enable(plan)
    res, got = _run_plan(coord, plan)
    pr = next(r for r in res.stats.pipelines if r.semijoin is not None)
    assert not pr.semijoin["applied"]
    assert pr.semijoin.get("reason") == "filter unavailable"
    _assert_same_rows(got, want, "bloomless cached build")


def test_chaos_kill_at_filter_publish_falls_back_to_parity():
    """A coordinator crash at the moment the merged filter is published
    re-drives the query; the rerun (filtered or not) must return the
    exact unfiltered rows — a lost filter can only cost performance."""
    store = ObjectStore(tier="local", seed=0)
    catalog = generate_tpch(store, sf=0.01, n_parts=4, seed=0)
    cfg = CoordinatorConfig(
        planner=PlannerConfig(**PLANNER), calibrate_selectivity=False,
        pipelined=True, max_attempts=6)
    chaos = ChaosEngine(ChaosConfig(
        kill_points=("registry.publish_filter",)))
    platform = FaasPlatform(quota=16, seed=0)
    session = connect(store, catalog, platform=platform, config=cfg,
                      registry=ResultRegistry(store, claim_ttl_s=0.25),
                      chaos=chaos)
    try:
        res = session.submit(SELECTIVE_JOIN).result(timeout=300)
        with chaos.pause():
            got = res.fetch(store)
    finally:
        session.close()
        platform.close()
    assert chaos.injected.get("kill:registry.publish_filter") == 1

    off = _coordinator(store, catalog, semijoin=False, seed=5)
    _, want = _run_plan(off, off.plan_sql(SELECTIVE_JOIN))
    _assert_same_rows(got, want, "chaos-killed filter publish")
