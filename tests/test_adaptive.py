"""Adaptive re-optimization at pipeline barriers (repro.core.adaptive):

* tentpole invariant — adaptive and static execution produce identical
  rows for every TPC-H query in the suite, while the adaptive path never
  invokes more workers;
* the cost-optimal fleet sizer (monotone in bytes, respects quota,
  latency budget, and the worker memory floor);
* empty-partition pruning, broadcast-join downgrade, skewed-selectivity
  fleet shrink, and EXPLAIN ANALYZE est-vs-actual reporting;
* priority admission: highest-priority waiter first, with aging.
"""

import threading
import time

import numpy as np
import pytest

from repro.api import CoordinatorConfig, connect
from repro.core.cost import CostModel
from repro.core.platform import AdmissionController
from repro.exec.operators import kmv_estimate, kmv_merge, kmv_sketch
from repro.sql.physical import PlannerConfig
from repro.sql.queries import QUERIES
from repro.storage import ColumnSpec, ObjectStore, write_pax
from repro.data.catalog import Catalog, TableMeta

PLANNER = PlannerConfig(bytes_per_worker=250_000,
                        broadcast_threshold_bytes=150_000,
                        exchange_partitions=3)


def _run(store, catalog, sql, *, adaptive, planner=PLANNER, quota=1000,
         pipelined=True):
    cfg = CoordinatorConfig(planner=planner, use_result_cache=False,
                            adaptive=adaptive, pipelined=pipelined)
    with connect(store, catalog, config=cfg, quota=quota) as session:
        handle = session.submit(sql)
        res = handle.result(timeout=300)
        cols = res.fetch(store)
        invocations = session.platform.invocations
    return cols, res.stats, invocations


def _sorted_rows(cols):
    keys = sorted(cols)
    arrs = [np.asarray(cols[k], np.float64) for k in keys]
    order = np.lexsort(arrs)
    return {k: a[order] for k, a in zip(keys, arrs)}


def _assert_same_rows(a, b, ctx=""):
    sa, sb = _sorted_rows(a), _sorted_rows(b)
    assert sorted(sa) == sorted(sb), ctx
    for k in sa:
        np.testing.assert_allclose(sa[k], sb[k], rtol=1e-9, atol=1e-9,
                                   err_msg=f"{ctx} :: {k}")


# -- tentpole: adaptive execution is row-identical on every TPC-H query -------

@pytest.mark.parametrize("qname", sorted(QUERIES))
def test_adaptive_matches_static_rows_tpch(tpch_store, qname):
    store, catalog = tpch_store
    static_cols, static_stats, _ = _run(store, catalog, QUERIES[qname],
                                        adaptive=False)
    adapt_cols, adapt_stats, _ = _run(store, catalog, QUERIES[qname],
                                      adaptive=True)
    _assert_same_rows(static_cols, adapt_cols, qname)
    static_workers = sum(p.n_fragments for p in static_stats.pipelines)
    adapt_workers = sum(p.n_fragments for p in adapt_stats.pipelines)
    assert adapt_workers <= static_workers, qname


# -- cost-optimal fleet sizer --------------------------------------------------

def test_optimal_fleet_monotone_in_bytes():
    cm = CostModel()
    sizes = [cm.optimal_fleet(nbytes, latency_budget_s=1.0,
                              max_workers=500)
             for nbytes in (0, 10**6, 10**8, 10**9, 10**10, 10**11)]
    assert sizes == sorted(sizes)
    assert sizes[0] == 1
    assert sizes[-1] > 1


def test_optimal_fleet_respects_latency_budget():
    cm = CostModel()
    nbytes = 10**10
    for budget in (0.5, 2.0, 10.0):
        w = cm.optimal_fleet(nbytes, latency_budget_s=budget,
                             max_workers=10_000)
        assert cm.fleet_latency_s(w, nbytes) <= budget
        # cost-minimal: one worker fewer would blow the budget
        if w > 1:
            assert cm.fleet_latency_s(w - 1, nbytes) > budget


def test_optimal_fleet_respects_quota_cap():
    cm = CostModel()
    assert cm.optimal_fleet(10**12, latency_budget_s=0.1,
                            max_workers=7) == 7
    assert cm.optimal_fleet(0, latency_budget_s=1.0, max_workers=7) == 1


def test_optimal_fleet_memory_floor():
    cm = CostModel(worker_memory_gib=2.0)
    # generous budget would allow 1 worker, but 100 GiB cannot fit one
    w = cm.optimal_fleet(100 << 30, latency_budget_s=10**9,
                         max_workers=10_000)
    assert w >= (100 << 30) // (2 << 30)


def test_fleet_cost_monotone_in_workers():
    cm = CostModel()
    costs = [cm.fleet_cost_cents(w, 10**9) for w in (1, 2, 8, 64, 512)]
    assert costs == sorted(costs)
    assert costs[0] < costs[-1]


# -- KMV distinct sketches -----------------------------------------------------

def test_kmv_sketch_estimates_distincts():
    from repro.exec.operators import np_key_hash
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 1000, 100_000).astype(np.int64)
    h = np_key_hash({"k": vals}, ["k"])
    est = kmv_estimate(kmv_sketch(h))
    assert 500 <= est <= 2000          # ~1000 distinct, coarse sketch
    # small sets are exact
    h3 = np_key_hash({"k": np.array([1, 2, 3, 2, 1])}, ["k"])
    assert kmv_estimate(kmv_sketch(h3)) == 3


def test_kmv_merge_unions_sketches():
    a = np.arange(0, 50, dtype=np.int64)
    b = np.arange(25, 75, dtype=np.int64)
    from repro.exec.operators import np_key_hash
    sa = kmv_sketch(np_key_hash({"k": a}, ["k"]))
    sb = kmv_sketch(np_key_hash({"k": b}, ["k"]))
    merged = kmv_merge([sa, sb])
    assert merged == sorted(merged)
    assert len(merged) == 32


# -- synthetic fact/dim database for targeted adaptation tests ----------------

FACT_SCHEMA = [
    ColumnSpec("f_key", "num", "<i8"),
    ColumnSpec("f_grp", "num", "<i8"),
    ColumnSpec("f_val", "num", "<f8"),
]
DIM_SCHEMA = [
    ColumnSpec("d_key", "num", "<i8"),
    ColumnSpec("d_x", "num", "<i8"),
]

import repro.sql.logical as _logical
_logical.PRIMARY_KEYS.setdefault("adim", "d_key")


def _make_db(rows=4000, dim_rows=50, n_parts=4, distinct_groups=2,
             seed=0):
    rng = np.random.default_rng(seed)
    fact = {
        "f_key": rng.integers(0, dim_rows, rows).astype(np.int64),
        "f_grp": rng.integers(0, distinct_groups, rows).astype(np.int64),
        "f_val": np.round(rng.normal(0, 10, rows), 3),
    }
    dim = {
        "d_key": np.arange(dim_rows, dtype=np.int64),
        "d_x": rng.integers(0, 5, dim_rows).astype(np.int64),
    }
    store = ObjectStore(tier="local", seed=seed)
    catalog = Catalog()
    files = []
    for p in range(n_parts):
        sel = slice(p * rows // n_parts, (p + 1) * rows // n_parts)
        key = f"db/afact/part-{p:05d}.spax"
        store.put(key, write_pax({k: v[sel] for k, v in fact.items()},
                                 FACT_SCHEMA))
        files.append(key)
    catalog.add(TableMeta("afact", FACT_SCHEMA, files, rows, 400_000))
    store.put("db/adim/part-00000.spax", write_pax(dim, DIM_SCHEMA))
    catalog.add(TableMeta("adim", DIM_SCHEMA, ["db/adim/part-00000.spax"],
                          dim_rows, 300_000))
    return store, catalog


def _adaptations(stats, kind=None):
    out = [a for p in stats.pipelines for a in p.adaptations]
    return [a for a in out if kind is None or a["kind"] == kind]


def test_empty_partition_pruning_and_resize():
    """A grouped exchange with only 2 distinct keys over 8 hash
    partitions: ≥6 partitions are provably empty; the adaptor prunes
    them and shrinks the merge fleet, with identical rows."""
    store, catalog = _make_db(distinct_groups=2)
    planner = PlannerConfig(bytes_per_worker=80_000,
                            broadcast_threshold_bytes=1,
                            exchange_partitions=8)
    sql = ("select f_grp, sum(f_val) as s, count(*) as n from afact "
           "group by f_grp order by f_grp")
    # barrier mode: the prune needs every producer's manifest — a
    # pipelined consumer admitted on the pilot-K fraction can never
    # prove a partition empty, so the adaptation is barrier-only
    static_cols, static_stats, static_inv = _run(
        store, catalog, sql, adaptive=False, planner=planner,
        pipelined=False)
    adapt_cols, adapt_stats, adapt_inv = _run(
        store, catalog, sql, adaptive=True, planner=planner,
        pipelined=False)
    _assert_same_rows(static_cols, adapt_cols, "pruning")
    prunes = _adaptations(adapt_stats, "partition_prune")
    assert prunes and prunes[0]["pruned"] >= 6
    resizes = _adaptations(adapt_stats, "fleet_resize")
    assert resizes and resizes[0]["to"] < resizes[0]["from"] == 8
    assert adapt_inv < static_inv


def test_broadcast_join_downgrade():
    """A repartition join whose observed build side fits the worker
    memory budget is downgraded to a broadcast read at the barrier,
    with identical rows."""
    store, catalog = _make_db()
    # tiny plan-time estimates threshold → static plan repartitions; the
    # runtime downgrade budget is set explicitly above the observed size
    planner = PlannerConfig(bytes_per_worker=80_000,
                            broadcast_threshold_bytes=1,
                            exchange_partitions=4)
    sql = ("select d_x, count(*) as n from afact, adim "
           "where f_key = d_key group by d_x order by d_x")
    cfg = CoordinatorConfig(planner=planner, use_result_cache=False,
                            adaptive=True,
                            broadcast_downgrade_bytes=1 << 20)
    static_cols, _, _ = _run(store, catalog, sql, adaptive=False,
                             planner=planner)
    with connect(store, catalog, config=cfg) as session:
        res = session.submit(sql).result(timeout=300)
        adapt_cols = res.fetch(store)
    downs = _adaptations(res.stats, "broadcast_downgrade")
    assert downs, "expected a broadcast downgrade"
    _assert_same_rows(static_cols, adapt_cols, "broadcast downgrade")


def test_skewed_selectivity_shrinks_fleet_and_cost():
    """A filter far more selective than the planner's guess (an
    expression predicate no zone map can estimate): the adaptive path
    re-sizes the join fleet down, invokes fewer workers, and spends
    deterministically fewer invocation cents — with identical rows."""
    store, catalog = _make_db(rows=8000)
    planner = PlannerConfig(bytes_per_worker=40_000,
                            broadcast_threshold_bytes=1,
                            exchange_partitions=6)
    # f_val + f_key < -30 is ~0.1% selective; the planner guesses 30%
    sql = ("select d_x, count(*) as n, sum(f_val) as s from afact, adim "
           "where f_key = d_key and f_val + f_key < -30 "
           "group by d_x order by d_x")
    static_cols, static_stats, static_inv = _run(
        store, catalog, sql, adaptive=False, planner=planner)
    adapt_cols, adapt_stats, adapt_inv = _run(
        store, catalog, sql, adaptive=True, planner=planner)
    _assert_same_rows(static_cols, adapt_cols, "skewed")
    resizes = _adaptations(adapt_stats, "fleet_resize")
    assert resizes and resizes[0]["to"] < resizes[0]["from"]
    assert adapt_inv < static_inv
    assert adapt_stats.cost.invoke_cents < static_stats.cost.invoke_cents


def test_explain_analyze_shows_est_vs_actual_and_adaptations():
    store, catalog = _make_db()
    planner = PlannerConfig(bytes_per_worker=80_000,
                            broadcast_threshold_bytes=1,
                            exchange_partitions=8)
    cfg = CoordinatorConfig(planner=planner, use_result_cache=False)
    sql = ("select f_grp, sum(f_val) as s from afact "
           "group by f_grp order by f_grp")
    with connect(store, catalog, config=cfg) as session:
        handle = session.submit(sql)
        text = handle.explain_analyze(timeout=300)
    assert "est≈" in text and "actual=" in text
    assert "adapted:" in text
    assert "→" in text                     # workers planned→invoked
    # plain EXPLAIN still shows the estimates
    with connect(store, catalog, config=cfg) as session:
        assert "rows≈" in session.explain(sql)


def test_adapted_pipeline_publishes_adapted_layout():
    """Downstream readers resolve the adapted fragment count from the
    registry entry, and the session counts adaptations."""
    store, catalog = _make_db(distinct_groups=2)
    planner = PlannerConfig(bytes_per_worker=80_000,
                            broadcast_threshold_bytes=1,
                            exchange_partitions=8)
    cfg = CoordinatorConfig(planner=planner, use_result_cache=False)
    sql = ("select f_grp, sum(f_val) as s from afact "
           "group by f_grp order by f_grp")
    with connect(store, catalog, config=cfg) as session:
        res = session.submit(sql).result(timeout=300)
        st = session.stats()
    adapted = [p for p in res.stats.pipelines if p.adaptations]
    assert adapted
    for p in adapted:
        assert p.n_fragments <= p.n_planned
    assert st["adaptations"] == sum(len(p.adaptations)
                                    for p in res.stats.pipelines)


# -- priority admission --------------------------------------------------------

def test_admission_grants_highest_priority_waiter_first():
    adm = AdmissionController(1, aging_interval_s=3600.0)
    adm.acquire(1)                     # occupy the only slot
    order = []

    def waiter(prio, tag):
        adm.acquire(1, priority=prio)
        order.append(tag)
        adm.release(1)

    t_low = threading.Thread(target=waiter, args=(0, "low"))
    t_low.start()
    while len(adm._waiters) < 1:
        time.sleep(0.005)
    t_high = threading.Thread(target=waiter, args=(5, "high"))
    t_high.start()
    while len(adm._waiters) < 2:
        time.sleep(0.005)
    adm.release(1)                     # freed slot → the p5 waiter
    t_low.join(timeout=30)
    t_high.join(timeout=30)
    assert order == ["high", "low"]
    assert adm.in_flight == 0


def test_admission_aging_prevents_starvation():
    """A long-waiting low-priority waiter overtakes a fresh
    high-priority one once its aging bump exceeds the gap."""
    adm = AdmissionController(1, aging_interval_s=0.05)
    adm.acquire(1)
    order = []

    def waiter(prio, tag):
        adm.acquire(1, priority=prio)
        order.append(tag)
        adm.release(1)

    t_low = threading.Thread(target=waiter, args=(0, "aged-low"))
    t_low.start()
    while len(adm._waiters) < 1:
        time.sleep(0.005)
    time.sleep(0.6)                    # aging bump ≈ 12 levels
    t_high = threading.Thread(target=waiter, args=(10, "fresh-high"))
    t_high.start()
    while len(adm._waiters) < 2:
        time.sleep(0.005)
    adm.release(1)
    t_low.join(timeout=30)
    t_high.join(timeout=30)
    assert order == ["aged-low", "fresh-high"]


def test_session_runs_high_priority_query_first(tpch_store):
    store, catalog = tpch_store
    cfg = CoordinatorConfig(planner=PLANNER, use_result_cache=False)
    started = []

    from repro.api import QueryObserver

    class Track(QueryObserver):
        def on_query_state(self, query_id, state):
            if state == "RUNNING":
                started.append(query_id)

    with connect(store, catalog, config=cfg, max_concurrent_queries=1,
                 observers=(Track(),)) as session:
        session.pause()
        h_low = session.submit(QUERIES["q6"], priority=0)
        h_high = session.submit(QUERIES["q1"], priority=5)
        session.resume()
        h_low.result(timeout=300)
        h_high.result(timeout=300)
    assert started.index(h_high.query_id) < started.index(h_low.query_id)
