"""Query service tier: durable request ledger (CAS transitions, lease
expiry, crash recovery with exactly-one fleet execution), weighted
fair-share admission with cost budgets, multi-query DAGs with shared
subplan dedup, the store-level watch primitive, and SLO deadline →
fleet-sizing plumbing."""

import threading
import time

import numpy as np
import pytest

from repro.api import CoordinatorConfig, FaasPlatform, connect
from repro.core.cost import CostModel
from repro.core.engine import QueryCancelled, QueryEngine
from repro.core.platform import AdmissionController
from repro.data import generate_tpch
from repro.service import (FairShareAdmission, LedgerConflict, QueryService,
                           RequestFailed, RequestLedger, RequestStatus,
                           ServiceHandle, TenantConfig, topological_order,
                           validate_dag)
from repro.sql.physical import PlannerConfig
from repro.sql.queries import QUERIES
from repro.storage import FilesystemBackend, ObjectStore

CFG = CoordinatorConfig(planner=PlannerConfig(
    bytes_per_worker=250_000, broadcast_threshold_bytes=150_000,
    exchange_partitions=3))


def _fresh_db(seed=0, tier="local", n_parts=4):
    store = ObjectStore(tier=tier, seed=seed)
    catalog = generate_tpch(store, sf=0.01, n_parts=n_parts, seed=0)
    return store, catalog


def _service(store, catalog, *, tenants=(), quota=16, lease_ttl_s=30.0,
             start=True):
    platform = FaasPlatform(quota=quota, seed=0)
    session = connect(store, catalog, platform=platform, config=CFG,
                      max_concurrent_queries=4)
    svc = QueryService(session, tenants=tuple(tenants),
                       lease_ttl_s=lease_ttl_s, start=start)
    return svc, session


def _solo_invocations(sql):
    """Worker invocations one clean execution of ``sql`` needs."""
    store, catalog = _fresh_db()
    platform = FaasPlatform(quota=16, seed=0)
    with connect(store, catalog, platform=platform, config=CFG,
                 max_concurrent_queries=4) as session:
        session.sql(sql)
    return platform.invocations


# -- store-level watch primitive (satellite) ----------------------------------

def _watch_store(backend, tmp_path):
    """Memory backend (CV notify path) vs filesystem backend (version
    polling with exponential backoff)."""
    if backend == "fs":
        return ObjectStore(FilesystemBackend(str(tmp_path / "store")),
                           tier="local", seed=0)
    return ObjectStore(tier="local", seed=0)


@pytest.mark.parametrize("backend", ["memory", "fs"])
def test_watch_wakes_on_put(backend, tmp_path):
    store = _watch_store(backend, tmp_path)
    store.put("w/k", b"v1")
    token = store.version("w/k")
    woke = []

    def waiter():
        woke.append(store.watch("w/k", token, timeout_s=10.0))

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    store.put("w/k", b"v2")
    t.join(timeout=10.0)
    assert not t.is_alive()
    assert woke and woke[0] != token


@pytest.mark.parametrize("backend", ["memory", "fs"])
def test_watch_observes_create_and_delete(backend, tmp_path):
    store = _watch_store(backend, tmp_path)
    assert store.version("w/absent") is None
    store.put("w/absent", b"x")         # creation: None → token
    assert store.watch("w/absent", None, timeout_s=0.5) is not None
    token = store.version("w/absent")
    store.delete("w/absent")            # deletion: token → None
    assert store.watch("w/absent", token, timeout_s=5.0) is None


def test_watch_timeout_returns_unchanged_token():
    store = ObjectStore(tier="local", seed=0)
    store.put("w/t", b"v")
    token = store.version("w/t")
    t0 = time.monotonic()
    assert store.watch("w/t", token, timeout_s=0.1) == token
    assert time.monotonic() - t0 < 2.0


def test_watch_cancel_check_aborts_wait():
    store = ObjectStore(tier="local", seed=0)
    store.put("w/c", b"v")

    class _Stop(Exception):
        pass

    def cancel():
        raise _Stop

    with pytest.raises(_Stop):
        store.watch("w/c", store.version("w/c"), timeout_s=30.0,
                    cancel_check=cancel)


# -- ledger: CAS transitions --------------------------------------------------

def _ledger(lease_ttl_s=30.0):
    return RequestLedger(ObjectStore(tier="local", seed=0),
                         lease_ttl_s=lease_ttl_s)


def test_ledger_lifecycle_and_versioning():
    led = _ledger()
    e = led.submit("select 1", tenant="t", priority=2, deadline_s=9.0)
    assert e.status is RequestStatus.QUEUED and e.version == 1
    got = led.get(e.request_id)
    assert got.sql == "select 1" and got.tenant == "t"
    assert got.priority == 2 and got.deadline_s == 9.0

    claimed = led.claim(e.request_id, "svc-a")
    assert claimed.status is RequestStatus.ADMITTED
    assert claimed.owner == "svc-a" and claimed.version == 2
    assert claimed.lease_expires > time.time()

    run = led.transition(e.request_id, RequestStatus.RUNNING,
                         if_owner="svc-a")
    assert run.version == 3 and run.started_at is not None

    done = led.transition(e.request_id, RequestStatus.SUCCEEDED,
                          if_owner="svc-a", result={"rows": 1})
    assert done.owner is None and done.finished_at is not None
    assert done.result == {"rows": 1}


def test_ledger_rejects_duplicate_stale_foreign_and_illegal():
    led = _ledger()
    led.submit("q", request_id="r1")
    with pytest.raises(LedgerConflict):        # duplicate id
        led.submit("q2", request_id="r1")
    led.claim("r1", "svc-a")
    with pytest.raises(LedgerConflict):        # stale version
        led.transition("r1", RequestStatus.RUNNING, expected_version=1)
    with pytest.raises(LedgerConflict):        # foreign owner
        led.transition("r1", RequestStatus.RUNNING, if_owner="svc-b")
    with pytest.raises(LedgerConflict):        # illegal: ADMITTED→SUCCEEDED
        led.transition("r1", RequestStatus.SUCCEEDED, if_owner="svc-a")
    led.transition("r1", RequestStatus.RUNNING, if_owner="svc-a")
    led.transition("r1", RequestStatus.SUCCEEDED, if_owner="svc-a")
    with pytest.raises(LedgerConflict):        # terminal states are final
        led.transition("r1", RequestStatus.QUEUED)
    assert led.claim("r1", "svc-b") is None    # claim loses, returns None
    with pytest.raises(LedgerConflict):        # unknown request id
        led.transition("ghost", RequestStatus.CANCELLED)


def test_ledger_double_claim_single_winner():
    led = _ledger()
    led.submit("q", request_id="r")
    wins = [led.claim("r", f"svc-{i}") for i in range(4)]
    assert sum(w is not None for w in wins) == 1
    assert led.get("r").owner == "svc-0"


def test_ledger_lease_expiry_requeues_and_bumps_attempt():
    led = _ledger(lease_ttl_s=0.05)
    led.submit("q", request_id="r")
    led.claim("r", "svc-dead")
    assert led.recover_expired() == []          # lease still live
    time.sleep(0.1)
    recovered = led.recover_expired()
    assert [e.request_id for e in recovered] == ["r"]
    e = led.get("r")
    assert e.status is RequestStatus.QUEUED
    assert e.owner is None and e.attempt == 1
    # renew_lease from the dead owner must now fail
    assert not led.renew_lease("r", "svc-dead")
    # a live owner renewing *before* expiry keeps the entry out of
    # recovery
    led.claim("r", "svc-live")
    assert led.renew_lease("r", "svc-live")
    assert led.recover_expired() == []
    # ...but once the lease lapses, even the original owner is fenced:
    # recovery may already have handed the request to a peer, so a late
    # renewal must not resurrect ownership
    time.sleep(0.1)
    assert not led.renew_lease("r", "svc-live")
    assert [e.request_id for e in led.recover_expired()] == ["r"]
    assert led.get("r").owner is None


def test_ledger_entries_filters_and_orders():
    led = _ledger()
    led.submit("a", request_id="ra", tenant="t1")
    led.submit("b", request_id="rb", tenant="t2")
    led.submit("c", request_id="rc", tenant="t1")
    led.claim("rb", "svc")
    assert [e.request_id for e in led.entries()] == ["ra", "rb", "rc"]
    assert [e.request_id for e in led.entries(tenant="t1")] == ["ra", "rc"]
    assert [e.request_id
            for e in led.entries(status=RequestStatus.ADMITTED)] == ["rb"]


def test_ledger_watch_wakes_handle_waiters():
    led = _ledger()
    led.submit("q", request_id="r")
    token = led.version_token("r")
    led.claim("r", "svc")
    assert led.watch("r", token, timeout_s=5.0) != token


# -- service: end-to-end ------------------------------------------------------

def test_service_executes_and_persists_result(tpch_store):
    store, catalog = tpch_store
    svc, session = _service(store, catalog)
    try:
        h = svc.submit(QUERIES["q6"])
        res = h.result(timeout=300)
        cols = res.fetch(store)
        assert len(cols["revenue"]) == 1
        entry = h.entry()
        assert entry.status is RequestStatus.SUCCEEDED
        assert entry.owner is None and entry.finished_at is not None
        assert entry.result["locations"] or entry.result["cache_hits"]
        # the ledger record alone resolves the data (durable handle)
        h2 = ServiceHandle(h.request_id, svc)
        np.testing.assert_allclose(
            h2.fetch(timeout=10)["revenue"], cols["revenue"])
    finally:
        svc.close()
        session.close()


def test_service_records_failure_and_cancel(tpch_store):
    store, catalog = tpch_store
    svc, session = _service(store, catalog)
    try:
        bad = svc.submit("select no_such_col from lineitem")
        with pytest.raises(RequestFailed):
            bad.result(timeout=120)
        assert bad.entry().error

        # a QUEUED request cancels without ever dispatching
        svc.kill()
        queued = svc.submit(QUERIES["q1"])
        assert queued.cancel()
        with pytest.raises(QueryCancelled):
            queued.result(timeout=10)
    finally:
        svc.close()
        session.close()


# -- service: crash recovery (tentpole acceptance) ----------------------------

@pytest.mark.parametrize("die_at", [RequestStatus.ADMITTED,
                                    RequestStatus.RUNNING])
def test_recovery_of_orphaned_entry_runs_fleet_exactly_once(die_at):
    """An owner that died right after reaching ``die_at`` (before any
    worker ran) leaves an orphan; a fresh service must re-queue it on
    lease expiry and execute it with exactly one fleet's invocations."""
    solo = _solo_invocations(QUERIES["q6"])
    store, catalog = _fresh_db()
    ledger = RequestLedger(store, lease_ttl_s=0.2)
    ledger.submit(QUERIES["q6"], request_id="r")
    ledger.claim("r", "svc-dead")
    if die_at is RequestStatus.RUNNING:
        ledger.transition("r", RequestStatus.RUNNING, if_owner="svc-dead")
    assert ledger.get("r").status is die_at
    time.sleep(0.25)                   # owner never renews: lease expires

    platform = FaasPlatform(quota=16, seed=0)
    session = connect(store, catalog, platform=platform, config=CFG,
                      max_concurrent_queries=4)
    svc = QueryService(session, ledger=ledger, lease_ttl_s=0.2)
    try:
        h = ServiceHandle("r", svc)
        entry = h.wait(timeout=120)
        assert entry.status is RequestStatus.SUCCEEDED
        assert entry.attempt == 1      # the re-queue was recorded
        assert svc.recovered_requests >= 1
        assert platform.invocations == solo    # exactly one execution
        assert len(h.fetch(timeout=30)["revenue"]) == 1
    finally:
        svc.close()
        session.close()


def test_crash_mid_running_second_instance_no_duplicate_fleet():
    """Kill the owning service while its query is RUNNING. The engine's
    published pipeline results make recovery duplicate-free: the second
    instance's re-run is pure cache — the platform sees exactly one
    fleet's worth of invocations across both instances."""
    solo = _solo_invocations(QUERIES["q6"])
    store, catalog = _fresh_db()
    ledger = RequestLedger(store, lease_ttl_s=0.3)
    platform = FaasPlatform(quota=16, seed=0)
    s1 = connect(store, catalog, platform=platform, config=CFG,
                 max_concurrent_queries=4)
    svc1 = QueryService(s1, ledger=ledger, lease_ttl_s=0.3)
    h = svc1.submit(QUERIES["q6"])
    deadline = time.monotonic() + 60
    while h.status() is not RequestStatus.RUNNING \
            and not h.status().terminal and time.monotonic() < deadline:
        time.sleep(0.002)
    pre_kill = h.status()
    svc1.kill()        # process death: no terminal record, lease orphaned
    s1.drain()         # the handed-off engine still finishes + publishes
    time.sleep(0.4)    # ... while the ledger lease quietly expires

    s2 = connect(store, catalog, platform=platform, config=CFG,
                 max_concurrent_queries=4)
    svc2 = QueryService(s2, ledger=ledger, lease_ttl_s=0.3)
    try:
        assert pre_kill is RequestStatus.RUNNING
        entry = h.wait(timeout=120)
        assert entry.status is RequestStatus.SUCCEEDED
        assert entry.owner is None
        assert platform.invocations == solo    # zero duplicate fleet work
        assert s2.registry.claims == 0         # re-run was pure cache
        cols = ServiceHandle(h.request_id, svc2).fetch(timeout=30)
        assert len(cols["revenue"]) == 1
    finally:
        svc2.close()
        s2.close()
        s1.close()


def test_restarted_service_resumes_queued_backlog():
    """A service that dies with QUEUED work leaves a durable backlog a
    fresh instance over the same ledger picks up unprompted."""
    store, catalog = _fresh_db()
    ledger = RequestLedger(store, lease_ttl_s=0.3)
    platform = FaasPlatform(quota=16, seed=0)
    s1 = connect(store, catalog, platform=platform, config=CFG)
    svc1 = QueryService(s1, ledger=ledger, start=False)   # never dispatches
    h = svc1.submit(QUERIES["q6"])
    assert h.status() is RequestStatus.QUEUED
    s1.close()

    s2 = connect(store, catalog, platform=platform, config=CFG,
                 max_concurrent_queries=4)
    svc2 = QueryService(s2, ledger=ledger, lease_ttl_s=0.3)
    try:
        entry = ServiceHandle(h.request_id, svc2).wait(timeout=120)
        assert entry.status is RequestStatus.SUCCEEDED
    finally:
        svc2.close()
        s2.close()
        platform.close()


# -- fair share (tentpole acceptance) -----------------------------------------

def test_fair_share_converges_to_weight_ratio():
    """Two groups flooding an 8-slot quota at weights 3:1 — admitted
    slots converge to the weight ratio within ±20%."""
    adm = AdmissionController(8, shares={"gold": 3.0, "bronze": 1.0})
    stop = threading.Event()

    def flood(group):
        while not stop.is_set():
            got = adm.acquire(1, group=group)
            time.sleep(0.001)
            adm.release(got)

    threads = [threading.Thread(target=flood, args=(g,))
               for g in ("gold", "bronze") for _ in range(8)]
    for t in threads:
        t.start()
    time.sleep(2.0)
    stop.set()
    for t in threads:
        t.join()
    admitted = adm.admitted_by_group
    assert admitted["gold"] > 0 and admitted["bronze"] > 0
    ratio = admitted["gold"] / admitted["bronze"]
    assert 3.0 * 0.8 <= ratio <= 3.0 * 1.2, admitted


def test_fair_share_unweighted_waiters_keep_priority_order():
    """Waiters without a registered share fall back to priority+aging
    ordering — the pre-service scheduler is unchanged."""
    adm = AdmissionController(1, shares={"g": 2.0})
    hold = adm.acquire(1)
    order = []
    lock = threading.Lock()

    def take(tag, prio):
        got = adm.acquire(1, priority=prio)
        with lock:
            order.append(tag)
        adm.release(got)

    threads = []
    for tag, prio in (("low", 0), ("high", 5)):
        t = threading.Thread(target=take, args=(tag, prio))
        t.start()
        threads.append(t)
        time.sleep(0.05)        # deterministic arrival order
    adm.release(hold)
    for t in threads:
        t.join()
    assert order[0] == "high"


def test_budget_throttles_then_window_rolls_over():
    """An over-budget tenant is throttled (not admitted) inside the
    window, degraded near the limit, and admissible again after the
    window rolls — throttling is bounded, never starvation."""
    adm = AdmissionController(4)
    fair = FairShareAdmission(adm, (TenantConfig(
        "t", budget_cents=10.0, budget_window_s=0.3,
        degrade_fraction=0.8),))
    assert fair.admissible("t") and not fair.degraded("t")
    fair.charge("t", 9.0)                   # past 80% → degraded
    assert fair.admissible("t") and fair.degraded("t")
    fair.charge("t", 2.0)                   # past 100% → throttled
    assert not fair.admissible("t")
    time.sleep(0.35)                        # window rollover
    assert fair.admissible("t") and not fair.degraded("t")
    st = fair.stats()["t"]
    assert st["throttled_admissions"] >= 1
    assert st["degraded_dispatches"] >= 1
    assert st["lifetime_cents"] == pytest.approx(11.0)
    # unknown / unmetered tenants are never limited
    assert fair.admissible(None) and fair.admissible("ghost")


def test_service_throttles_over_budget_tenant_but_not_forever(tpch_store):
    store, catalog = tpch_store
    svc, session = _service(store, catalog, tenants=(
        TenantConfig("broke", budget_cents=1e-6, budget_window_s=0.5),))
    try:
        svc.admission.charge("broke", 1.0)  # exhaust the window budget
        h = svc.submit(QUERIES["q6"], tenant="broke")
        time.sleep(0.15)
        assert h.status() is RequestStatus.QUEUED     # throttled
        # the next window admits it: throttling is bounded
        entry = h.wait(timeout=300)
        assert entry.status is RequestStatus.SUCCEEDED
        assert svc.stats()["tenants"]["broke"]["throttled_admissions"] >= 1
    finally:
        svc.close()
        session.close()


# -- DAGs (tentpole acceptance) -----------------------------------------------

def test_dag_validation_and_topological_order():
    assert topological_order(3, {}) == [0, 1, 2]
    assert topological_order(3, {2: [0, 1], 1: [0]}) == [0, 1, 2]
    assert topological_order(3, {0: [2], 1: [0]}) == [2, 0, 1]
    assert topological_order(2, {0: [1], 1: [0]}) is None      # cycle
    with pytest.raises(ValueError):
        validate_dag(2, {0: [1], 1: [0]})
    with pytest.raises(ValueError):
        validate_dag(2, {0: [0]})                              # self-dep
    with pytest.raises(ValueError):
        validate_dag(2, {0: [5]})                              # range
    with pytest.raises(ValueError):
        validate_dag(1, {3: []})


def test_dag_respects_depends_on_and_shares_subplans(tpch_store):
    """node1 depends on node0 and contains the same plan: it must start
    only after node0 SUCCEEDED and must not re-execute the shared
    pipelines (cache/dedup hits instead)."""
    store, catalog = tpch_store
    svc, session = _service(store, catalog)
    try:
        handles = svc.submit_dag(
            [QUERIES["q6"], QUERIES["q6"]], {1: [0]})
        e1 = handles[1].wait(timeout=300)
        e0 = handles[0].entry()
        assert e0.status is RequestStatus.SUCCEEDED
        assert e1.status is RequestStatus.SUCCEEDED
        assert e0.dag_id == e1.dag_id
        assert e1.depends_on == [e0.request_id]
        # ordering: the dependent only started after its dependency's
        # terminal record was written
        assert e1.started_at >= e0.finished_at
        # shared subplan materialized exactly once: node1 is all hits
        assert e1.result["cache_hits"] + e1.result["deduped"] >= 1
        np.testing.assert_allclose(
            handles[0].fetch(timeout=10)["revenue"],
            handles[1].fetch(timeout=10)["revenue"])
    finally:
        svc.close()
        session.close()


def test_dag_failed_dependency_fails_dependents(tpch_store):
    store, catalog = tpch_store
    svc, session = _service(store, catalog)
    try:
        handles = svc.submit_dag(
            ["select no_such_col from lineitem", QUERIES["q6"]],
            {1: [0]})
        with pytest.raises(RequestFailed):
            handles[0].result(timeout=120)
        with pytest.raises(RequestFailed):
            handles[1].result(timeout=120)
        assert "dependency" in handles[1].entry().error
    finally:
        svc.close()
        session.close()


# -- SLO deadlines → fleet sizing ---------------------------------------------

def test_stage_latency_budget_splits_remaining_deadline():
    cm = CostModel()
    assert cm.stage_latency_budget(10.0, 0.0, 2) == pytest.approx(5.0)
    assert cm.stage_latency_budget(10.0, 6.0, 2) == pytest.approx(2.0)
    # blown deadline degrades to the floor, never negative
    assert cm.stage_latency_budget(10.0, 20.0, 2) == \
        pytest.approx(0.001 / 2)
    assert cm.stage_latency_budget(10.0, 0.0, 0) == pytest.approx(10.0)


def _scan_fleet(deadline_s=None, fleet_cap=None):
    store, catalog = _fresh_db()
    engine = QueryEngine(
        store, catalog, platform=FaasPlatform(quota=32, seed=0),
        config=CoordinatorConfig(
            planner=PlannerConfig(bytes_per_worker=30_000),
            use_result_cache=False),
        deadline_s=deadline_s, fleet_cap=fleet_cap)
    res = engine.execute_sql("select l_quantity from lineitem")
    return res.stats.pipelines


def test_tight_deadline_escalates_fleet():
    """The same query under a tight SLO deadline must scan with at
    least as many workers as under a loose one."""
    tight = _scan_fleet(deadline_s=0.01)[0].n_fragments
    loose = _scan_fleet(deadline_s=1e6)[0].n_fragments
    assert tight >= loose
    assert tight > 1       # a near-zero budget widens the scan fleet


def test_fleet_cap_clamps_every_pipeline():
    pipelines = _scan_fleet(fleet_cap=1)
    assert all(p.n_fragments == 1 for p in pipelines)
    assert any(a["kind"] == "deadline_fleet"
               for p in pipelines for a in p.adaptations)


def test_deadline_miss_is_recorded_by_service():
    # fresh store: a result-cache hit would (correctly) meet any SLO
    store, catalog = _fresh_db()
    svc, session = _service(store, catalog, tenants=(
        TenantConfig("slo", deadline_s=1e-9),))    # unmeetable
    try:
        h = svc.submit(QUERIES["q6"], tenant="slo")
        res = h.result(timeout=300)
        assert res.deadline_missed
        assert svc.stats()["deadline_misses"] >= 1
        assert h.entry().deadline_s == 1e-9        # tenant default applied
    finally:
        svc.close()
        session.close()
