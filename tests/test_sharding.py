"""Distribution/sharding tests.

The production dry-run needs 512 placeholder devices, which must be pinned
before jax initializes — so the mesh-level test runs in a subprocess; the
in-process tests cover the sharding rule logic (pure functions of shapes
and mesh metadata) without touching device state.
"""

import json
import os
import subprocess
import sys

import jax

SUB = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json
import jax
import jax.numpy as jnp
from repro.configs import get_reduced
from repro.launch.specs import build_cell
from repro.parallel import sharding as sh
from repro.analysis.roofline import analyze

from repro.launch.mesh import auto_axis_kwargs
mesh = jax.make_mesh((2, 2, 4), ("pod", "data", "model"),
                     **auto_axis_kwargs(3))
plan = sh.make_plan(mesh)
cfg = get_reduced("granite-3-2b")
import dataclasses
cfg = dataclasses.replace(cfg, d_model=128, d_ff=256, n_heads=8,
                          n_kv_heads=4, vocab=256)
cell = build_cell(cfg, "granite-3-2b", "train_4k", mesh=mesh)
# shrink the batch for speed: rebuild batch specs
b = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
     "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
p, o = cell.arg_specs[0], cell.arg_specs[1]
in_sh = (sh.param_shardings(plan, p),
         sh.opt_state_shardings(plan, p, o),
         sh.batch_shardings(plan, b))
with mesh:
    compiled = jax.jit(cell.step_fn, in_shardings=in_sh,
                       out_shardings=(in_sh[0], in_sh[1], None)
                       ).lower(p, o, b).compile()
roof = analyze(compiled)
print(json.dumps({
    "ok": True,
    "flops": roof.flops_per_device,
    "collective_bytes": roof.collective_bytes_per_device,
    "n_devices": 16,
}))
"""


def test_multi_pod_mesh_lowers_in_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SUB], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]
    assert rec["flops"] > 0
    assert rec["collective_bytes"] > 0  # DP/TP collectives present


def test_sharding_rules_divisibility_guards():
    from repro.parallel.sharding import MeshPlan, _spec

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    plan = MeshPlan(FakeMesh(), ("data",))
    # strict: 49155 not divisible by 16 → dropped
    assert _spec(plan, (49155, 2048), ("model", "data"))[0] is None
    # relaxed: kept (GSPMD pads)
    assert _spec(plan, (49155, 2048), ("model", "data"),
                 strict=False)[0] == "model"
    # dim smaller than axis: always dropped
    assert _spec(plan, (8, 64), ("model", None),
                 strict=False)[0] is None
    assert _spec(plan, (2048, 512), (None, "model"))[1] == "model"


def test_model_flops_estimates():
    from repro.analysis.roofline import model_flops
    from repro.configs import get_config
    from repro.models.config import SHAPES

    cfg = get_config("llama3-405b")
    mf = model_flops(cfg, SHAPES["train_4k"], train=True)
    # 6 · 405e9 · (256·4096) ≈ 2.5e18
    assert 2.0e18 < mf < 3.2e18, mf
    mf_dec = model_flops(cfg, SHAPES["decode_32k"], train=False)
    assert mf_dec < mf / 1e4
