"""Distributed engine vs numpy oracle on the paper's TPC-H workload."""

import numpy as np
import pytest

from repro.core import (CoordinatorConfig, FaasPlatform, QueryCoordinator)
from repro.sql import oracle
from repro.sql.logical import Binder
from repro.sql.parser import parse
from repro.sql.physical import PlannerConfig
from repro.sql.queries import QUERIES
from repro.sql.rules import optimize

CFG = CoordinatorConfig(planner=PlannerConfig(
    bytes_per_worker=250_000, broadcast_threshold_bytes=150_000,
    exchange_partitions=3))


def _run(store, catalog, sql):
    coord = QueryCoordinator(store, catalog, platform=FaasPlatform(seed=1),
                             config=CFG)
    res = coord.execute_sql(sql)
    return res.fetch(store), res


def _oracle(catalog, tables, sql):
    plan, _ = Binder(catalog).bind(parse(sql))
    return oracle.run(optimize(plan), tables)


@pytest.mark.parametrize("qname", ["q1", "q3", "q6", "q12", "q14", "q19"])
def test_tpch_query_matches_oracle(qname, tpch_store, tpch_tables):
    store, catalog = tpch_store
    got, _ = _run(store, catalog, QUERIES[qname])
    want = _oracle(catalog, tpch_tables, QUERIES[qname])
    assert set(want).issubset(set(got))
    for k in want:
        np.testing.assert_allclose(
            np.asarray(got[k], np.float64), np.asarray(want[k], np.float64),
            rtol=1e-9, atol=1e-9, err_msg=f"{qname}.{k}")


def test_filter_only_query(tpch_store, tpch_tables):
    store, catalog = tpch_store
    sql = ("select o_orderkey, o_totalprice from orders "
           "where o_totalprice > 300000 and o_orderstatus = 'F'")
    got, _ = _run(store, catalog, sql)
    want = _oracle(catalog, tpch_tables, sql)
    got_sorted = np.sort(got["o_orderkey"])
    want_sorted = np.sort(want["o_orderkey"])
    assert np.array_equal(got_sorted, want_sorted)


def test_order_by_limit(tpch_store, tpch_tables):
    store, catalog = tpch_store
    sql = ("select o_orderkey, o_totalprice from orders "
           "order by o_totalprice desc, o_orderkey limit 7")
    got, _ = _run(store, catalog, sql)
    want = _oracle(catalog, tpch_tables, sql)
    assert np.array_equal(got["o_orderkey"], want["o_orderkey"])


def test_broadcast_join_path(tpch_store, tpch_tables):
    # huge broadcast threshold → join executes as broadcast
    store, catalog = tpch_store
    cfg = CoordinatorConfig(planner=PlannerConfig(
        bytes_per_worker=250_000, broadcast_threshold_bytes=1 << 30))
    coord = QueryCoordinator(store, catalog,
                             platform=FaasPlatform(seed=2), config=cfg)
    res = coord.execute_sql(QUERIES["q12"])
    got = res.fetch(store)
    want = _oracle(catalog, tpch_tables, QUERIES["q12"])
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k], np.float64),
                                   np.asarray(want[k], np.float64))
    # only 2 pipelines: orders build + lineitem scan/join/agg + final
    assert len(res.stats.pipelines) == 3


def test_avg_decomposition(tpch_store, tpch_tables):
    store, catalog = tpch_store
    sql = ("select l_returnflag, avg(l_quantity) as aq, count(*) as c "
           "from lineitem group by l_returnflag order by l_returnflag")
    got, _ = _run(store, catalog, sql)
    want = _oracle(catalog, tpch_tables, sql)
    np.testing.assert_allclose(np.asarray(got["aq"]),
                               np.asarray(want["aq"]), rtol=1e-12)
