"""SkyriseSession public API: concurrent multi-query execution over one
shared FaaS quota, cross-session result-cache sharing, query lifecycle
(queued-cancel never invokes a worker), explain-only planning, and the
QueryCoordinator deprecation shim."""

import numpy as np
import pytest

from repro.api import (ConsoleObserver, CoordinatorConfig, FaasPlatform,
                       QueryCancelled, QueryObserver, QueryState, connect)
from repro.core import QueryCoordinator
from repro.data import generate_tpch
from repro.sql.physical import PlannerConfig
from repro.sql.queries import QUERIES
from repro.storage import ObjectStore

CFG = CoordinatorConfig(planner=PlannerConfig(
    bytes_per_worker=250_000, broadcast_threshold_bytes=150_000,
    exchange_partitions=3))


def _fresh_db(seed=0, tier="local"):
    store = ObjectStore(tier=tier, seed=seed)
    catalog = generate_tpch(store, sf=0.01, n_parts=4, seed=0)
    return store, catalog


def test_connect_builds_session_and_runs_sql():
    store, catalog = _fresh_db()
    with connect(store, catalog, config=CFG) as session:
        res = session.sql(QUERIES["q6"])
        cols = res.fetch(store)
        assert len(cols["revenue"]) == 1
        assert res.stats.sim_latency_s > 0
        assert res.stats.cost.total_cents > 0


def test_handle_lifecycle_and_stats():
    store, catalog = _fresh_db()
    with connect(store, catalog, config=CFG) as session:
        h = session.submit(QUERIES["q6"])
        res = h.result(timeout=120)
        assert h.state is QueryState.SUCCEEDED
        assert h.done()
        assert h.stats() is res.stats
        assert h.stats().query_id == h.query_id
        # terminal handles can no longer be cancelled
        assert not h.cancel()
        assert h.state is QueryState.SUCCEEDED


def test_concurrent_queries_share_quota_and_never_exceed_it():
    """≥4 concurrently submitted queries, one shared platform: combined
    in-flight workers stay within the quota (wave admission spans
    queries, not just fragments of one pipeline)."""
    store, catalog = _fresh_db()
    quota = 3
    platform = FaasPlatform(quota=quota, seed=0)
    cfg = CoordinatorConfig(planner=CFG.planner, use_result_cache=False)
    with connect(store, catalog, platform=platform, config=cfg,
                 max_concurrent_queries=4) as session:
        handles = [session.submit(QUERIES[q])
                   for q in ("q1", "q6", "q12", "q14")]
        results = [h.result(timeout=300) for h in handles]
    assert all(h.state is QueryState.SUCCEEDED for h in handles)
    adm = platform.admission
    assert 1 <= adm.max_in_flight <= quota
    assert adm.in_flight == 0                   # everything released
    # all four queries really ran workers on the one platform
    total_frags = sum(p.n_fragments for r in results
                      for p in r.stats.pipelines)
    assert platform.invocations >= total_frags > quota


def test_concurrent_submissions_match_sequential_results():
    store, catalog = _fresh_db(tier="s3-standard")
    seq = {}
    with connect(store, catalog, config=CFG) as session:
        for q in ("q1", "q12"):
            seq[q] = session.sql(QUERIES[q]).fetch(store)

    store2, catalog2 = _fresh_db(tier="s3-standard")
    with connect(store2, catalog2, config=CFG, quota=4,
                 max_concurrent_queries=2) as session:
        handles = {q: session.submit(QUERIES[q]) for q in ("q1", "q12")}
        for q, h in handles.items():
            got = h.result(timeout=300).fetch(store2)
            for k in seq[q]:
                np.testing.assert_allclose(
                    np.asarray(got[k], np.float64),
                    np.asarray(seq[q][k], np.float64),
                    err_msg=f"{q}.{k}")


def test_two_sessions_share_result_cache_through_store():
    """Section 3.4 across sessions: the semantic cache lives in the
    store, so a second session skips every pipeline the first ran."""
    store, catalog = _fresh_db()
    platform = FaasPlatform(seed=0)

    with connect(store, catalog, platform=platform, config=CFG) as s1:
        r1 = s1.sql(QUERIES["q12"])
    assert r1.stats.cache_hits == 0

    inv_before = platform.invocations
    with connect(store, catalog, platform=platform, config=CFG) as s2:
        h = s2.submit(QUERIES["q12"])
        st = h.stats(timeout=120)
    assert st.cache_hits == len(st.pipelines)   # visible in handle.stats()
    assert platform.invocations == inv_before   # zero new workers
    # both directions: s2 primes a query, s1's store serves it to a
    # brand-new third session
    with connect(store, catalog, platform=platform, config=CFG) as s3:
        st3 = s3.submit(QUERIES["q12"]).stats(timeout=120)
    assert st3.cache_hits == len(st3.pipelines)


def test_cancel_queued_handle_never_invokes_worker():
    store, catalog = _fresh_db()
    platform = FaasPlatform(seed=0)
    with connect(store, catalog, platform=platform, config=CFG) as session:
        session.pause()                   # admission gate: nothing runs
        h = session.submit(QUERIES["q1"])
        assert h.state is QueryState.QUEUED
        assert h.cancel()
        session.resume()
        assert h.wait(timeout=60)
        assert h.state is QueryState.CANCELLED
        with pytest.raises(QueryCancelled):
            h.result(timeout=10)
    assert platform.invocations == 0


def test_multi_fragment_root_result_is_fully_fetched():
    """The result location(s) come from the registry entry, not a
    hardcoded f0000 — a root pipeline split across fragments must
    return every row."""
    store, catalog = _fresh_db()
    # tiny bytes_per_worker → the lineitem scan splits into >1 fragment;
    # a projection-only query keeps the scan pipeline as root
    cfg = CoordinatorConfig(planner=PlannerConfig(bytes_per_worker=50_000))
    with connect(store, catalog, config=cfg) as session:
        res = session.sql(
            "select l_quantity, l_extendedprice from lineitem")
        root_report = res.stats.pipelines[-1]
        assert len(res.locations) > 1, \
            "expected a multi-fragment root pipeline"
        cols = res.fetch(store)
    n_lineitem = catalog.table("lineitem").rows
    assert len(cols["l_quantity"]) == n_lineitem
    assert root_report.n_fragments == len(res.locations)


def test_explain_plans_without_invoking_workers():
    store, catalog = _fresh_db()
    platform = FaasPlatform(seed=0)
    with connect(store, catalog, platform=platform, config=CFG) as session:
        text = session.explain(QUERIES["q3"])
    assert "pipeline" in text and "root" in text
    assert platform.invocations == 0


def test_observer_receives_lifecycle_and_pipeline_events():
    events = []

    class Recorder(QueryObserver):
        def on_query_state(self, query_id, state):
            events.append(("state", state))

        def on_pipeline_start(self, query_id, pid, sem_hash, n_fragments):
            events.append(("start", pid))

        def on_pipeline_complete(self, query_id, report):
            events.append(("complete", report.pid, report.cache_hit))

    store, catalog = _fresh_db()
    with connect(store, catalog, config=CFG,
                 observers=(Recorder(),)) as session:
        session.sql(QUERIES["q6"])
        session.sql(QUERIES["q6"])          # cached second run
    states = [e[1] for e in events if e[0] == "state"]
    assert states.count("PLANNING") == 2
    assert states.count("SUCCEEDED") == 2
    assert any(e[0] == "start" for e in events)
    assert any(e[0] == "complete" and e[2] for e in events)  # cache hit


def test_console_observer_smoke(capsys):
    import io
    buf = io.StringIO()
    store, catalog = _fresh_db()
    with connect(store, catalog, config=CFG,
                 observers=(ConsoleObserver(out=buf),)) as session:
        session.sql(QUERIES["q6"])
    out = buf.getvalue()
    assert "RUNNING" in out and "pipeline" in out


def test_query_coordinator_shim_still_works_and_warns():
    store, catalog = _fresh_db()
    with pytest.warns(DeprecationWarning, match="SkyriseSession"):
        coord = QueryCoordinator(store, catalog,
                                 platform=FaasPlatform(seed=0), config=CFG)
    res = coord.execute_sql(QUERIES["q6"])
    cols = res.fetch(store)
    assert len(cols["revenue"]) == 1
    # old single-location attribute still present
    assert res.location == res.locations[0]


def test_connect_rejects_conflicting_arguments():
    store, catalog = _fresh_db()
    with pytest.raises(ValueError, match="platform or quota"):
        connect(store, catalog, platform=FaasPlatform(seed=0), quota=8)
    with pytest.raises(ValueError, match="store or store_dir"):
        connect(store, catalog, tier="local")


def test_operations_without_catalog_raise_actionable_error():
    session = connect(tier="local")
    with pytest.raises(RuntimeError, match="no catalog attached"):
        session.submit(QUERIES["q6"])
    with pytest.raises(RuntimeError, match="no catalog attached"):
        session.explain(QUERIES["q6"])


def test_failed_query_surfaces_error_and_state():
    store, catalog = _fresh_db()
    with connect(store, catalog, config=CFG) as session:
        h = session.submit("select nope from lineitem")
        assert h.wait(timeout=120)
        assert h.state is QueryState.FAILED
        assert h.error() is not None
        with pytest.raises(Exception):
            h.result(timeout=10)
