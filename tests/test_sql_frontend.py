"""Parser, binder, logical optimizer, and semantic hashing."""

import pytest

from repro.data.tpch import date_to_int
from repro.sql import ast
from repro.sql.logical import (Binder, BindError, LAggregate, LFilter,
                               LJoin, LProject, LScan, semantic_hash)
from repro.sql.parser import parse
from repro.sql.physical import PlannerConfig, compile_query
from repro.sql.queries import QUERIES
from repro.sql.rules import optimize


def _bind(sql, catalog):
    plan, schema = Binder(catalog).bind(parse(sql))
    return optimize(plan), schema


def test_parse_q1_shape():
    stmt = parse(QUERIES["q1"])
    assert stmt.tables == ("lineitem",)
    assert len(stmt.items) == 10
    assert len(stmt.group_by) == 2
    assert stmt.order_by[0].desc is False


def test_parse_errors():
    with pytest.raises(SyntaxError):
        parse("select from lineitem")
    with pytest.raises(SyntaxError):
        parse("select a lineitem")  # missing from


def test_date_interval_folding(tpch_store):
    _, catalog = tpch_store
    plan, _ = _bind(
        "select l_orderkey from lineitem where "
        "l_shipdate < date '1994-02-28' + interval '1' year", catalog)
    found = [n for n in _walk_nodes(plan) if isinstance(n, LFilter)]
    lit = found[0].pred.right
    assert lit.value == date_to_int("1995-02-28")


def test_dict_literal_rewrite(tpch_store):
    _, catalog = tpch_store
    plan, _ = _bind(
        "select l_orderkey from lineitem where l_shipmode = 'MAIL'",
        catalog)
    filt = [n for n in _walk_nodes(plan) if isinstance(n, LFilter)][0]
    assert filt.pred.right.value == 2  # MAIL's code in SHIPMODE


def test_like_prefix_rewrites_to_codes(tpch_store):
    _, catalog = tpch_store
    plan, _ = _bind(
        "select p_partkey from part where p_type like 'PROMO%'", catalog)
    filt = [n for n in _walk_nodes(plan) if isinstance(n, LFilter)][0]
    assert isinstance(filt.pred, ast.InList)
    assert len(filt.pred.values) == 25  # 5 syl2 × 5 syl3


def test_unknown_column_rejected(tpch_store):
    _, catalog = tpch_store
    with pytest.raises(BindError):
        _bind("select nope from lineitem", catalog)


def test_non_pk_join_rejected(tpch_store):
    _, catalog = tpch_store
    with pytest.raises(BindError):
        # partsupp.ps_partkey is not a PK (4 rows per part)
        _bind("select l_orderkey from lineitem, partsupp "
              "where l_partkey = ps_partkey", catalog)


def test_projection_pruning_narrows_scan(tpch_store):
    _, catalog = tpch_store
    plan, _ = _bind("select l_orderkey from lineitem "
                    "where l_shipdate > date '1995-01-01'", catalog)
    scan = [n for n in _walk_nodes(plan) if isinstance(n, LScan)][0]
    assert set(scan.schema_cols) == {"l_orderkey", "l_shipdate"}


def test_filter_pushdown_below_join(tpch_store):
    _, catalog = tpch_store
    plan, _ = _bind(
        "select o_orderkey from orders, lineitem "
        "where o_orderkey = l_orderkey and l_quantity < 10 "
        "and o_totalprice > 1000", catalog)
    join = [n for n in _walk_nodes(plan) if isinstance(n, LJoin)][0]
    # both filters must now sit below the join
    assert any(isinstance(n, LFilter) for n in _walk_nodes(join.left))
    assert any(isinstance(n, LFilter) for n in _walk_nodes(join.right))


def test_semantic_hash_ignores_physical_properties(tpch_store):
    """Section 3.4: cache identifiers are independent of worker counts and
    exchange fan-outs."""
    _, catalog = tpch_store
    plan, _ = _bind(QUERIES["q12"], catalog)
    cfg_a = PlannerConfig(bytes_per_worker=100_000, exchange_partitions=2)
    cfg_b = PlannerConfig(bytes_per_worker=10_000_000,
                          exchange_partitions=8)
    pa = compile_query(plan, catalog, cfg_a)
    pb = compile_query(plan, catalog, cfg_b)
    ha = {p.sem_hash for p in pa.pipelines.values()}
    hb = {p.sem_hash for p in pb.pipelines.values()}
    assert ha == hb
    na = {p.sem_hash: p.n_fragments for p in pa.pipelines.values()}
    nb = {p.sem_hash: p.n_fragments for p in pb.pipelines.values()}
    assert na != nb  # physical plans genuinely differ


def test_semantic_hash_distinguishes_queries(tpch_store):
    _, catalog = tpch_store
    p1, _ = _bind(QUERIES["q1"], catalog)
    p6, _ = _bind(QUERIES["q6"], catalog)
    assert semantic_hash(p1) != semantic_hash(p6)


def test_q12_pipeline_structure(tpch_store):
    """Paper Fig. 3: Q12 = two scan pipelines feeding a join+partial-agg
    pipeline, then the final aggregation."""
    _, catalog = tpch_store
    plan, _ = _bind(QUERIES["q12"], catalog)
    pq = compile_query(plan, catalog,
                       PlannerConfig(bytes_per_worker=200_000,
                                     broadcast_threshold_bytes=100_000,
                                     exchange_partitions=4))
    stages = pq.stages()
    assert len(stages) == 3
    assert len(stages[0]) == 2          # lineitem + orders scans
    join_pipe = pq.pipelines[stages[1][0]]
    assert join_pipe.op["t"] == "partial_agg"
    assert join_pipe.op["child"]["t"] == "join"
    assert pq.pipelines[pq.root_pid].final


def _walk_nodes(node):
    yield node
    for c in node.children():
        yield from _walk_nodes(c)
